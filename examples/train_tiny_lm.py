"""Train a small LM for a few hundred steps with the full training substrate
(data pipeline -> model -> Adam -> metrics). Uses a reduced tinyllama-family
config sized for CPU; the same train_step lowers at production scale in the
multi-pod dry-run.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.data.tokens import SyntheticTokenPipeline
from repro.models.model import build_model
from repro.train.optimizer import AdamConfig, adam_init, adam_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = smoke_variant(get_config("tinyllama-1.1b")).replace(
        num_layers=4, d_model=256, d_ff=512, vocab_size=512, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

    pipe = SyntheticTokenPipeline(vocab=cfg.vocab_size, seq_len=args.seq,
                                  batch=args.batch, seed=0)
    opt_cfg = AdamConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        params, opt, om = adam_update(opt_cfg, grads, opt, params)
        return params, opt, loss, om["grad_norm"]

    t0 = time.time()
    first_loss = None
    for i in range(args.steps):
        batch = pipe.next_batch()
        params, opt, loss, gnorm = step(params, opt, batch)
        if i == 0:
            first_loss = float(loss)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} |g| {float(gnorm):.3f}")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps*args.batch*args.seq/dt:.0f} tok/s)")
    print(f"loss: {first_loss:.3f} -> {float(loss):.3f} "
          f"({'learned' if float(loss) < first_loss - 0.5 else 'check lr'})")


if __name__ == "__main__":
    main()
