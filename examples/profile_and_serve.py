"""End-to-end measured-profiling demo (the paper's §5 Profiler, live):

  1. PROFILE   — sweep a real three-variant ladder on the in-process engine
                 across the paper's allocation points; regression-fit
                 th(n) = a·n + b and p(n) = base + k/n from measurements.
  2. PERSIST   — register everything in the versioned profile store
                 (reports/profiles/), together with cross-calibrated
                 roofline profiles for a TPU-scale ladder the CPU cannot
                 run; save, reload, and serve from the *loaded* store.
  3. SERVE     — run the InfAdapter control loop against the engine using
                 the measured profiles (units -> concurrency enforced, so
                 profiled capacity is live capacity).
  4. DRIFT     — slow the engine down (decode chunk cut 4 -> 1 plus
                 simulated host contention stalling every decode chunk)
                 and serve again: the drift detector flags the stale
                 profile.
  5. RECAL     — targeted re-profile of only the drifted variant; the
                 store is patched, the controller's profile swapped, and
                 the Eq. 1 solver's allocation shifts.

Run:  PYTHONPATH=src python examples/profile_and_serve.py [--seconds 12]
"""
import argparse
import os
import time

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.adapter import ControllerConfig, InfAdapterController
from repro.core.forecaster import MovingMaxForecaster
from repro.profiling.calibrate import profile_unrunnable
from repro.profiling.drift import DriftDetector, OnlineRecalibrator
from repro.profiling.measure import EngineProfiler
from repro.profiling.store import DEFAULT_STORE_DIR, ProfileStore
from repro.serving.api import Request
from repro.serving.driver import rise_fall_load, run_serving_loop
from repro.serving.engine import InProcessServingEngine

SLO_MS = 2000.0


def build_ladder():
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=128, vocab_size=256)
    return {
        "tiny-2L": (base.replace(num_layers=2, name="tiny-2L"), 70.0),
        "tiny-4L": (base.replace(num_layers=4, name="tiny-4L"), 75.0),
        "tiny-6L": (base.replace(num_layers=6, name="tiny-6L"), 78.0),
    }


def make_engine(variants, decode_chunk):
    return InProcessServingEngine(variants, max_batch=8, prompt_len=16,
                                  max_new=8, decode_chunk=decode_chunk,
                                  enforce_units=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=int, default=12)
    ap.add_argument("--interval", type=float, default=4.0)
    args = ap.parse_args()

    variants = build_ladder()
    engine = make_engine(variants, decode_chunk=4)

    # -- 1. PROFILE: measured sweep over the paper's allocation points -------
    print("== profiling variants from engine measurements ==")
    profiler = EngineProfiler(engine, points=(1, 2, 4, 8),
                              requests_per_point=16, warmup=4)
    store = ProfileStore(os.path.join(DEFAULT_STORE_DIR, "demo.json"))
    measurements = profiler.profile_all(store=store)
    for name, m in measurements.items():
        print(f"  {name}: th(n)={m.th_fit.slope:.1f}n{m.th_fit.intercept:+.1f} "
              f"rps (R2={m.th_fit.r_squared:.3f})  "
              f"p(n)={m.lat_base_ms:.1f}+{m.lat_k_ms:.1f}/n ms  "
              f"rt={m.readiness_s:.2f}s")

    # -- 2. PERSIST: + cross-calibrated roofline for an unrunnable ladder ----
    big = get_config("tinyllama-1.1b")
    profile_unrunnable(
        [big.replace(name="tinyllama-full")], [82.0], measurements,
        {n: variants[n][0] for n in variants}, store=store)
    path = store.save()
    loaded = ProfileStore.load(path)
    print(f"== store saved+reloaded: {path} ({len(loaded)} profiles) ==")
    for n in loaded.names():
        e = loaded.entry(n)
        print(f"  {n}: provenance={e.provenance}")

    # -- 3. SERVE with MEASURED profiles (not inline constants) --------------
    measured = {n: loaded.get(n) for n in variants}   # engine-servable subset
    cfg = ControllerConfig(interval_s=args.interval, budget=8, slo_ms=SLO_MS,
                           beta=0.05, gamma=0.05, queue_aware=True)
    ctrl = InfAdapterController(measured, MovingMaxForecaster(window=10), cfg)
    print(f"\n== serving {args.seconds}s with measured profiles ==")
    run_serving_loop(engine, ctrl, seconds=args.seconds,
                     interval=args.interval,
                     load_fn=rise_fall_load(args.seconds, lo=4.0, hi=24.0))
    s = engine.summarize(SLO_MS, best_accuracy=78.0)
    if s:
        print(f"served {s['n_requests']}: viol={s['violation_rate']:.1%} "
              f"p99={s['p99_ms']:.0f}ms queue~{s.get('mean_queue_ms', 0):.0f}ms "
              f"service~{s.get('mean_service_ms', 0):.0f}ms")

    # -- 4. DRIFT: cut the decode chunk + simulate host contention -----------
    print("\n== injecting slowdown (decode_chunk 4 -> 1, +10ms contention "
          "per chunk) ==")
    slow = make_engine(variants, decode_chunk=1)
    detector = DriftDetector(loaded, tolerance=0.35, min_requests=8)
    last = ctrl.decisions[-1].allocation.units if ctrl.decisions else {}
    units = {m: n for m, n in last.items() if n > 0} or {"tiny-2L": 2}
    slow.apply_allocation(0.0, units)
    for b in slow.backends.values():        # a noisy neighbour stealing CPU
        b._decode_chunk = (lambda orig: lambda p, c, t:
                           (time.sleep(0.010), orig(p, c, t))[1])(b._decode_chunk)
    rng = np.random.default_rng(0)
    for i in range(24):
        name = list(units)[i % len(units)]
        slow.submit(Request(rid=i, tokens=rng.integers(0, 256, 16).astype(np.int64),
                            max_new=8, arrival=time.time()), name)
        slow.step(0.0)
    slow.drain(0.0)
    detector.observe_engine(slow)
    reports = detector.check_all(units)
    for rep in reports:
        flag = "DRIFTED" if rep.drifted else "ok"
        print(f"  {rep.variant}: {flag} service_ratio={rep.service_ratio:.2f} "
              f"({rep.reason or 'within band'})")

    # -- 5. RECAL: re-profile drifted variants, allocation shifts ------------
    slow_profiler = EngineProfiler(slow, points=(1, 2, 4),
                                   requests_per_point=10, warmup=3)
    recal = OnlineRecalibrator(slow_profiler, loaded, controller=ctrl,
                               detector=detector)
    drifted = [r.variant for r in reports if r.drifted]
    lam = ctrl.decisions[-1].predicted_load if ctrl.decisions else 16.0
    before = ctrl.decide(0.0, slow).allocation.units
    for name in drifted:
        m = recal.recalibrate(name)
        print(f"  recalibrated {name}: th(1) "
              f"{measured[name].throughput(1):.0f} -> "
              f"{m.profile.throughput(1):.0f} rps")
    after = ctrl.decide(0.0, slow).allocation.units
    print(f"\n== allocation for lam={lam:.0f} rps: {before} -> {after} ==")
    loaded.save()
    print(f"store updated: {path}")


if __name__ == "__main__":
    main()
