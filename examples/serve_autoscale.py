"""End-to-end driver: serve REAL JAX models with continuous batching behind
the InfAdapter control loop (the serving analogue of "train a 100M model").

A three-variant tinyllama-family ladder (2/4/6 layers) is served by the
in-process engine; the controller profiles each variant live (readiness time
and measured throughput), then adapts the variant set as synthetic load rises
and falls — driving the engine purely through the shared ``ClusterAPI`` /
``ServingAPI`` contract (``repro.serving.api``), the same interface the
discrete-event simulator implements. Everything here executes real model
code — prefill, slot-based continuous batching against the persistent KV
ring buffer, jitted decode chunks — on CPU.

Cluster-fabric demo flags: ``--replicas``/``--nodes`` shard each variant
into single-unit replicas placed across that many nodes
(``repro.cluster.ReplicaFabric`` behind the same ``ServingAPI``), and
``--fail-node-at T`` crashes node0 T seconds in (recovering at T+8) so the
retry + controller-re-placement path runs on real models.

Run:  PYTHONPATH=src python examples/serve_autoscale.py [--seconds 30]
      [--mode continuous|pump]   (pump = legacy micro-batching baseline)
      [--scheduler fifo|edf|chunked] [--preemption none|requeue|drop]
      [--replicas 3 --nodes 3 --fail-node-at 12]
"""
import argparse
import os

from repro.cluster import FaultSchedule, make_nodes, node_crash, node_recover
from repro.configs import get_config, smoke_variant
from repro.core.adapter import ControllerConfig, InfAdapterController
from repro.core.forecaster import MovingMaxForecaster
from repro.profiling.measure import EngineProfiler
from repro.profiling.store import DEFAULT_STORE_DIR, ProfileStore
from repro.serving.api import ClusterAPI, ServingAPI
from repro.serving.driver import ElapsedClock, rise_fall_load, run_serving_loop
from repro.serving.engine import InProcessServingEngine


def build_ladder():
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(d_model=128)
    # pseudo-accuracies from the documented scaling-law proxy
    return {
        "tiny-2L": (base.replace(num_layers=2, name="tiny-2L"), 70.0),
        "tiny-4L": (base.replace(num_layers=4, name="tiny-4L"), 75.0),
        "tiny-6L": (base.replace(num_layers=6, name="tiny-6L"), 78.0),
    }


def calibrate(engine, variants):
    """Measured profiles via the profiling subsystem: the ``EngineProfiler``
    sweeps each variant across allocation points, the results persist in the
    profile store, and the controller loads from the *store* — no inline
    profile constants (see DESIGN.md §Profiling)."""
    profiler = EngineProfiler(engine, points=(1, 2, 4),
                              requests_per_point=12, warmup=3, max_units=4)
    store = ProfileStore(os.path.join(DEFAULT_STORE_DIR, "serve_autoscale.json"))
    measurements = profiler.profile_all(store=store)
    for name, m in measurements.items():
        print(f"  {name}: th(n)={m.th_fit.slope:.1f}n{m.th_fit.intercept:+.1f} "
              f"req/s (R2={m.th_fit.r_squared:.2f}), readiness "
              f"{m.readiness_s:.2f}s, p(1)~{m.profile.p99_ms(1):.0f} ms")
    store.save()
    return ProfileStore.load(store.path).profiles()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=int, default=24)
    ap.add_argument("--interval", type=float, default=6.0)
    ap.add_argument("--mode", choices=("continuous", "pump"),
                    default="continuous")
    ap.add_argument("--scheduler", default="fifo",
                    choices=("fifo", "edf", "chunked"),
                    help="queue-to-slot scheduling discipline "
                         "(DESIGN.md §Scheduling)")
    ap.add_argument("--preemption", default="none",
                    choices=("none", "requeue", "drop"),
                    help="retire deadline-hopeless residents for feasible "
                         "waiters (requeue resumes them, tokens preserved)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="shard variants into single-unit replicas across "
                         "the node set (0 = legacy monolithic backends)")
    ap.add_argument("--nodes", type=int, default=0,
                    help="node count for the replica fabric "
                         "(default: --replicas)")
    ap.add_argument("--fail-node-at", type=float, default=None,
                    help="crash node0 this many seconds in (recovers 8 s "
                         "later) — exercises retry + re-placement")
    ap.add_argument("--trace", action="store_true",
                    help="record request lifecycle spans + per-tick phase "
                         "costs and export reports/TRACE_engine.json "
                         "(Perfetto-loadable), METRICS_engine.jsonl, and "
                         "AUDIT_decisions.jsonl")
    ap.add_argument("--report-dir", default="reports",
                    help="where --trace writes its artifacts")
    ap.add_argument("--burn-rate-alerts", action="store_true",
                    help="turn on rolling windows + the SLO burn-rate "
                         "monitor; the controller re-solves immediately "
                         "when the fast AND slow burn-rate windows breach "
                         "(DESIGN.md §Observability, online tier)")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="arm the anomaly flight recorder: dump a "
                         "Perfetto-loadable FLIGHT_<reason>.json of the "
                         "recent past into DIR on burn-rate alerts and "
                         "injected faults")
    ap.add_argument("--async-tick", action="store_true",
                    help="two-phase dispatch/commit tick loop: each tick "
                         "dispatches its jitted exec before committing the "
                         "previous tick's tokens, hiding the D2H read and "
                         "bookkeeping behind device compute (DESIGN.md "
                         "§Async tick loop; greedy outputs are bitwise "
                         "identical to the sync default)")
    ap.add_argument("--speculative", default=None,
                    metavar="DRAFTER:VERIFIER",
                    help="speculative decoding on the variant ladder "
                         "(e.g. tiny-2L:tiny-6L): the drafter proposes "
                         "--spec-k tokens per round and the verifier "
                         "scores them in one batched step, committing the "
                         "longest agreeing prefix + one bonus token — "
                         "greedy outputs stay bitwise identical to "
                         "verifier-only decoding (DESIGN.md §Speculative "
                         "decoding)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft length per speculative round")
    args = ap.parse_args()

    variants = build_ladder()
    fabric_on = (args.replicas > 0 or args.nodes > 0
                 or args.fail_node_at is not None)
    budget = max(args.replicas, 2) if fabric_on else 3
    engine_kw = dict(max_batch=8, prompt_len=16, mode=args.mode, max_new=8,
                     decode_chunk=4, scheduler=args.scheduler,
                     preemption=args.preemption, clock=ElapsedClock(),
                     trace=args.trace, async_tick=args.async_tick)
    if args.speculative:
        engine_kw.update(speculative=args.speculative, spec_k=args.spec_k)
    # online tier: rolling windows feed the burn-rate monitor; the flight
    # recorder rides the tracer and dumps on alerts/faults
    flight = None
    if args.burn_rate_alerts or args.flight_dir:
        from repro.obs import FlightRecorder, Observability
        if args.flight_dir:
            os.makedirs(args.flight_dir, exist_ok=True)
            flight = FlightRecorder(out_dir=args.flight_dir)
        engine_kw["obs"] = Observability(trace=args.trace, windows=True,
                                         flight=flight)
    if fabric_on:
        n_nodes = args.nodes or max(args.replicas, 2)
        # room for create-then-remove surge and for re-placement after a
        # node crash
        node_cap = max(2, -(-2 * budget // n_nodes))
        print(f"cluster fabric: {n_nodes} nodes × {node_cap} units, "
              f"replica_size=1 (budget {budget})")
        engine = InProcessServingEngine(
            variants, nodes=make_nodes(n_nodes, node_cap), replica_size=1,
            placement="spread", **engine_kw)
        # the profiler needs the legacy variant-keyed layout; profile on a
        # separate monolithic engine, serve on the fabric (offline
        # profiling, sharded serving). It keeps its own obs bundle so
        # calibration traffic never leaks into the serving windows/flight.
        prof_engine = InProcessServingEngine(
            variants, **{k: v for k, v in engine_kw.items() if k != "obs"})
    else:
        engine = InProcessServingEngine(variants, **engine_kw)
        prof_engine = engine
    # the whole control loop below sees the engine only through the shared
    # serving contract — swap in a SimCluster and nothing else changes
    assert isinstance(engine, ClusterAPI) and isinstance(engine, ServingAPI)
    print(f"calibrating variants (live profiling), mode={args.mode}...")
    profiles = calibrate(prof_engine, variants)
    if prof_engine is not engine:
        # free the calibration engine's params/KV state before serving
        prof_engine.apply_allocation(0.0, {})
        del prof_engine

    slo_ms = 2000.0
    cfg = ControllerConfig(interval_s=args.interval, budget=budget,
                           slo_ms=slo_ms, beta=0.05, gamma=0.05,
                           reactive=True, queue_aware=True)
    slo_monitor, sink = None, None
    if args.burn_rate_alerts:
        from repro.obs import (BurnRateRule, CollectingSink, FlightTrigger,
                               SLOMonitor)
        sink = CollectingSink()
        sinks = [sink] + ([FlightTrigger(flight)] if flight is not None
                          else [])
        slo_monitor = SLOMonitor(engine.windows, budget=0.05,
                                 rules=(BurnRateRule(fast_s=5.0, slow_s=30.0,
                                                     threshold=2.0),),
                                 sinks=tuple(sinks))
    ctrl = InfAdapterController(profiles, MovingMaxForecaster(window=10),
                                cfg, burn_alerts=sink)

    faults = None
    if args.fail_node_at is not None:
        faults = FaultSchedule([
            node_crash(args.fail_node_at, "node0"),
            node_recover(args.fail_node_at + 8.0, "node0")])
    print(f"\nserving for {args.seconds}s with a rising-falling load...")
    run_serving_loop(engine, ctrl, seconds=args.seconds,
                     interval=args.interval,
                     load_fn=rise_fall_load(max(args.seconds, 1)),
                     faults=faults, slo_ms=slo_ms, slo_monitor=slo_monitor)
    s = engine.summarize(slo_ms, best_accuracy=78.0)
    if not s:
        print(f"\nno requests completed ({engine.rejected} rejected)")
        return
    print(f"\nserved {s['n_requests']} requests ({s.get('rejected', 0)} "
          f"rejected): goodput={s['goodput']:.1%} "
          f"viol={s['violation_rate']:.1%} p99={s['p99_ms']:.0f}ms "
          f"mean={s['mean_latency_ms']:.0f}ms acc_loss={s['accuracy_loss']:.2f}%")

    if slo_monitor is not None:
        n_burn = sum(1 for d in ctrl.audit.entries
                     if d.reason == "burn_rate")
        print(f"burn-rate alerts: {len(slo_monitor.alerts)} fired, "
              f"{n_burn} re-solves")
    if flight is not None:
        for p in flight.dumps:
            print(f"flight dump: {p}")

    if args.trace:
        from repro.obs.export import (write_audit_jsonl, write_chrome_trace,
                                      write_metrics_jsonl)
        os.makedirs(args.report_dir, exist_ok=True)
        tp = os.path.join(args.report_dir, "TRACE_engine.json")
        mp = os.path.join(args.report_dir, "METRICS_engine.jsonl")
        ap_ = os.path.join(args.report_dir, "AUDIT_decisions.jsonl")
        n_ev = write_chrome_trace(tp, engine.tracer, label="serve_autoscale")
        n_m = write_metrics_jsonl(
            mp, engine.metrics,
            extra=[{"name": "run.config", "kind": "meta",
                    "scheduler": args.scheduler, "mode": args.mode,
                    "seconds": args.seconds, "slo_ms": slo_ms}])
        n_d = write_audit_jsonl(ap_, ctrl.audit)
        asum = ctrl.audit.summary()
        print(f"\ntrace: {tp} ({n_ev} events; load in Perfetto/chrome://tracing)")
        print(f"metrics: {mp} ({n_m} series)")
        print(f"audit: {ap_} ({n_d} decisions, "
              f"{asum.get('n_measured', 0):.0f} measured; "
              f"goodput regret {asum.get('mean_abs_goodput_regret', float('nan')):.3f}, "
              f"p99 regret {asum.get('mean_p99_regret_ms', float('nan')):+.0f} ms)")


if __name__ == "__main__":
    main()
